"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
experiments/dryrun/*.json, plus the §Sampling throughput table when
``benchmarks.bench_sampling_throughput --json`` output is present under
experiments/sampling/, the §Lowering backend table from the trajectory
records ``benchmarks.bench_flops_efficiency`` appends under
experiments/lowering/, the §Hoisting table (naive vs two-phase
sliced execution) from the records ``benchmarks.bench_slicing_overhead``
appends under experiments/hoisting/, the §Memory table (peak-aware
slicer vs width proxy + fused transpose credit) from the records the
same benchmark's ``memory_rows`` appends under experiments/memory/, the §Co-optimizer table (one-shot
pipeline vs anytime plan_search) from the records
``benchmarks.bench_slice_count.cooptimizer_rows`` appends under
experiments/optimize/, the §Megakernel table (epilogue fused-chain
ablation) from the records ``benchmarks.bench_end_to_end`` appends
under experiments/megakernel/, and the §Observability table (tracer
overhead + model-vs-measured calibration) from the records
``bench_end_to_end.telemetry_rows`` appends under experiments/obs/.

    PYTHONPATH=src python -m benchmarks.make_tables > experiments/tables.md
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config

from .bench_roofline import enrich

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "llama3-405b", "llama3.2-3b", "qwen3-4b", "deepseek-7b", "zamba2-7b",
    "seamless-m4t-medium", "deepseek-moe-16b", "llama4-scout-17b-a16e",
    "qwen2-vl-72b", "mamba2-130m",
]


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def load(dryrun_dir="experiments/dryrun"):
    """One record per cell: the run whose sharding recipe matches the
    arch's production recipe (experiment variants like __rfsdp_only are
    §Perf baselines, not table rows)."""
    recs = {}
    for path in glob.glob(os.path.join(dryrun_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        arch = r.get("arch")
        try:
            want = get_config(arch).sharding_recipe
        except KeyError:
            continue
        got = r.get("recipe")
        if got is not None and got != want:
            continue
        key = (arch, r.get("shape"), "multi" in os.path.basename(path))
        recs[key] = r
    return recs


def print_sampling_table(sampling_dir="experiments/sampling") -> None:
    """§Sampling throughput rows (batched correlated-amplitude sampling),
    emitted only when the benchmark's JSON records exist."""
    paths = sorted(glob.glob(os.path.join(sampling_dir, "*.json")))
    if not paths:
        return
    print("\n### Batch-sampling throughput "
          "(one sliced contraction per 2^k batch)\n")
    print("| k open | batch | slices | wall | samples/s | "
          "batch amps/s | per-amp engine amps/s | XEB |")
    print("|---|---|---|---|---|---|---|---|")
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        for r in rec.get("records", []):
            print(
                f"| {r['k_open']} | {r['batch_size']} | {r['num_slices']} "
                f"| {fmt_s(r['wall_s'])} | {r['samples_per_s']:.0f} "
                f"| {r['amps_per_s']:.1f} "
                f"| {r['per_amp_engine_amps_per_s']:.1f} "
                f"| {r['xeb']:+.3f} |"
            )


def print_lowering_table(lowering_dir="experiments/lowering") -> None:
    """§Lowering backend rows (einsum oracle vs lowered-GEMM schedule),
    one row per trajectory record."""
    paths = sorted(glob.glob(os.path.join(lowering_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        rows.extend(rec.get("records", []))
    if not rows:
        return
    print("\n### Lowered-GEMM backend vs einsum oracle (stem workload)\n")
    print("| workload | einsum wall | gemm wall | gemm/einsum | "
          "schedule (nodes per backend) | pad waste |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        be = r.get("backends", {})
        sched = be.get("gemm", {}).get("schedule", {})
        counts = ", ".join(
            f"{k}:{v}" for k, v in sorted(sched.get("backends", {}).items())
        ) or "-"
        print(
            f"| {r.get('workload', '-')} "
            f"| {fmt_s(be.get('einsum', {}).get('wall_s'))} "
            f"| {fmt_s(be.get('gemm', {}).get('wall_s'))} "
            f"| {r.get('gemm_over_einsum', float('nan')):.2f}× "
            f"| {counts} "
            f"| {sched.get('pad_waste', 0.0)*100:.1f}% |"
        )


def print_hoisting_table(hoisting_dir="experiments/hoisting") -> None:
    """§Hoisting rows: naive (full tree per slice, Eq. 4) vs two-phase
    lifetime-partitioned execution, one row per trajectory record."""
    paths = sorted(glob.glob(os.path.join(hoisting_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        rows.extend(rec.get("records", []))
    if not rows:
        return
    print("\n### Two-phase sliced execution "
          "(slice-invariant hoisting vs naive, Eq. 4)\n")
    print("| workload | backend | slices | inv. nodes | naive ov (Eq. 4) | "
          "hoisted ov | scan wall (naive / hoisted warm) | "
          "per-slice wall (naive / hoisted) | per-slice speedup |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        inv = (
            f"{r['invariant_nodes']}/{r['total_nodes']}"
            if "invariant_nodes" in r else "-"
        )
        wall_scan = (
            f"{fmt_s(r['wall_naive_s'])} / {fmt_s(r['wall_hoisted_warm_s'])}"
            if r.get("wall_naive_s") is not None else "-"
        )
        wall_ps = (
            f"{fmt_s(r['wall_perslice_naive_s'])} / "
            f"{fmt_s(r['wall_perslice_hoisted_s'])}"
            if r.get("wall_perslice_naive_s") is not None else "-"
        )
        speed = r.get("speedup_perslice")
        print(
            f"| {r.get('workload', '-')} "
            f"| {r.get('backend', 'modeled')} "
            f"| {1 << r.get('num_sliced', 0)} "
            f"| {inv} "
            f"| {r.get('naive_overhead', float('nan')):.3f} "
            f"| {r.get('hoisted_overhead', float('nan')):.3f} "
            f"| {wall_scan} | {wall_ps} "
            f"| {'-' if speed is None else f'{speed:.2f}×'} |"
        )


def print_memory_table(memory_dir="experiments/memory") -> None:
    """§Memory rows: width-proxy vs peak-aware slicing (lifetime-based
    buffer plans) + fused-kernel transpose credit, one row per
    trajectory record."""
    paths = sorted(glob.glob(os.path.join(memory_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows.extend(rec.get("records", []))
    if not rows:
        return
    print("\n### Lifetime-based memory planning "
          "(peak-aware slicer vs width proxy, fused transpose credit)\n")
    print("| workload | \\|S\\| width → peak | planned peak width → peak | "
          "byte budget | transpose bytes eliminated | "
          "wall width → peak | speedup |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if "num_sliced_width" not in r:
            continue
        wall = speed = "-"
        if r.get("wall_width_s") is not None:
            wall = (
                f"{fmt_s(r['wall_width_s'])} → {fmt_s(r['wall_peak_s'])}"
            )
            speed = f"{r['speedup_peak_over_width']:.2f}×"
        print(
            f"| {r.get('workload', '-')} "
            f"| {r['num_sliced_width']} → {r['num_sliced_peak']} "
            f"| {fmt_bytes(r['peak_bytes_width'])} → "
            f"{fmt_bytes(r['peak_bytes_peak'])} "
            f"| {fmt_bytes(r.get('budget_bytes'))} "
            f"| {fmt_bytes(r.get('transpose_bytes_eliminated'))} "
            f"| {wall} | {speed} |"
        )


def print_optimize_table(optimize_dir="experiments/optimize") -> None:
    """§Co-optimizer rows: one-shot staged pipeline vs the anytime
    path–slice co-optimizer at equal evaluation budget and equal
    certified-peak byte budget, one row per trajectory record."""
    paths = sorted(glob.glob(os.path.join(optimize_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows.extend(rec.get("records", []))
    if not rows:
        return
    print("\n### Anytime path–slice co-optimizer "
          "(one-shot pipeline vs plan_search, equal certified-peak "
          "budget)\n")
    print("| workload | evals | \\|S\\| one-shot → co-opt | "
          "log2 executed FLOPs (hoist-aware) | improvement | "
          "certified peak → budget | plan wall one-shot → search |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        if "log2_flops_oneshot" not in r:
            continue
        print(
            f"| {r.get('workload', '-')} "
            f"| {r.get('max_evals', '-')} "
            f"| {r['num_sliced_oneshot']} → {r['num_sliced_coopt']} "
            f"| {r['log2_flops_oneshot']:.2f} → "
            f"{r['log2_flops_coopt']:.2f} "
            f"| {r['improvement']:.2f}× "
            f"| {fmt_bytes(r['peak_bytes_coopt'])} → "
            f"{fmt_bytes(r['budget_bytes'])} "
            f"| {fmt_s(r.get('wall_oneshot_s'))} → "
            f"{fmt_s(r.get('wall_search_s'))} |"
        )


def print_megakernel_table(megakernel_dir="experiments/megakernel") -> None:
    """§Megakernel rows: the epilogue fused-chain ablation
    (REPRO_MEGAKERNEL on/off on the lowered GEMM schedule), one row per
    trajectory record."""
    paths = sorted(glob.glob(os.path.join(megakernel_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows.extend(rec.get("records", []))
    if not rows:
        return
    print("\n### Epilogue megakernel "
          "(VMEM-resident fused GEMM chains, REPRO_MEGAKERNEL ablation)\n")
    print("| workload | slices | fused chains | max len | chain peak | "
          "HBM saved/exec (per segment) | wall off → on | speedup |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if "fused_chains" not in r:
            continue
        saved = ", ".join(
            f"{seg}:{fmt_bytes(v)}"
            for seg, v in sorted(r.get("hbm_bytes_saved", {}).items())
        ) or "-"
        speed = r.get("speedup")
        print(
            f"| {r.get('workload', '-')} "
            f"| {1 << r.get('num_sliced', 0)} "
            f"| {r['fused_chains']} "
            f"| {r.get('max_chain_len', '-')} "
            f"| {fmt_bytes(r.get('chain_peak_bytes'))} "
            f"| {saved} "
            f"| {fmt_s(r.get('wall_megakernel_off_s'))} → "
            f"{fmt_s(r.get('wall_megakernel_on_s'))} "
            f"| {'-' if speed is None else f'{speed:.2f}×'} |"
        )


def print_distributed_table(distributed_dir="experiments/distributed") -> None:
    """§Multi-host rows: static uniform split vs LPT + work stealing
    (measured threaded walls on the synthetic ragged-cost overlay) and
    the real overlapped-reduction execution, one row per trajectory
    record from ``bench_distributed_scaling``."""
    paths = sorted(glob.glob(os.path.join(distributed_dir, "*.json")))
    rows = []
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows.extend(rec.get("records", []))
    sched = [r for r in rows if r.get("kind") == "scheduling"]
    execs = [r for r in rows if r.get("kind") == "execution"]
    if sched:
        print("\n### Multi-host scheduling "
              "(static uniform split vs LPT + work stealing, "
              "measured walls on ragged costs)\n")
        print("| workload | slices | hosts | imbalance static → steal | "
              "steals | wall static → steal | speedup |")
        print("|---|---|---|---|---|---|---|")
        for r in sched:
            print(
                f"| {r.get('workload', '-')} "
                f"| {r.get('n_slices', '-')} "
                f"| {r.get('hosts', '-')} "
                f"| {r.get('schedule_imbalance_static', 0):.2f} → "
                f"{r.get('schedule_imbalance', 0):.2f} "
                f"| {r.get('steal_count', '-')} "
                f"| {fmt_s(r.get('wall_static_s'))} → "
                f"{fmt_s(r.get('wall_steal_s'))} "
                f"| {r.get('speedup', 0):.2f}× |"
            )
    if execs:
        print("\n### Multi-host execution "
              "(contract_multihost, overlapped chunked all-reduce)\n")
        print("| workload | slices | executed | padded | overlap | "
              "max abs err | wall |")
        print("|---|---|---|---|---|---|---|")
        for r in execs:
            print(
                f"| {r.get('workload', '-')} "
                f"| {r.get('n_slices', '-')} "
                f"| {r.get('executed_slices', '-')} "
                f"| {r.get('padded_slices', '-')} "
                f"| {r.get('overlap_fraction', 0):.2f} "
                f"| {r.get('max_abs_err', 0):.1e} "
                f"| {fmt_s(r.get('wall_s'))} |"
            )


def print_obs_table(obs_dir="experiments/obs") -> None:
    """§Observability rows: tracer-overhead ablation (same compiled
    artifact, untraced vs traced wall) and the model-vs-measured
    calibration ratio per backend class, one row per (workload, class)
    from the trajectory records ``bench_end_to_end.telemetry_rows``
    appends."""
    path = os.path.join(obs_dir, "trajectory.json")
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows = rec.get("records", [])
    rows = [r for r in rows if "overhead_ratio" in r]
    if not rows:
        return
    print("\n### Observability "
          "(tracer overhead + model-vs-measured calibration)\n")
    print("| workload | slices | wall untraced → traced | overhead | "
          "class | steps | measured | modeled | meas/model |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        ratio = r.get("overhead_ratio")
        lead = (
            f"| {r.get('workload', '-')} "
            f"| {1 << r.get('num_sliced', 0)} "
            f"| {fmt_s(r.get('wall_untraced_s'))} → "
            f"{fmt_s(r.get('wall_traced_s'))} "
            f"| {'-' if ratio is None else f'{ratio:.3f}×'} "
        )
        by_class = (r.get("calibration") or {}).get("by_class", {})
        if not by_class:
            print(lead + "| - | - | - | - | - |")
            continue
        for i, (cls, agg) in enumerate(sorted(by_class.items())):
            head = lead if i == 0 else "| | | | "
            print(
                head
                + f"| {cls} | {agg['count']} "
                f"| {fmt_s(agg['measured_s'])} "
                f"| {fmt_s(agg['modeled_s'])} "
                f"| {agg['ratio']:.2f} |"
            )


def print_precision_table(precision_dir="experiments/precision") -> None:
    """§Mixed precision rows: fp32 vs auto plan on the pinned workload —
    modeled epilogue time, total HBM traffic, slice count, bf16 step
    counts, and the measured Linear-XEB delta, one row pair per
    trajectory record ``bench_end_to_end.precision_rows`` appends."""
    path = os.path.join(precision_dir, "trajectory.json")
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows = rec.get("records", [])
    rows = [r for r in rows if "xeb_delta" in r]
    if not rows:
        return
    print("\n### Mixed precision under an XEB budget "
          "(fp32 vs auto at fidelity_tol)\n")
    print("| workload | tol | mode | slices | bf16 steps | "
          "epilogue model | HBM bytes | wall | XEB | amp rel err |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        for mode in ("fp32", "auto"):
            s = r.get(mode) or {}
            counts = s.get("precision_counts") or {}
            total = sum(counts.values())
            xeb = r.get(f"xeb_{mode}")
            rel_err = (
                "" if mode == "fp32"
                else f"{r.get('amp_rel_err', 0):.2e}"
            )
            print(
                f"| {r.get('workload', '-') if mode == 'fp32' else ''} "
                f"| {r.get('fidelity_tol', '-') if mode == 'fp32' else ''} "
                f"| {mode} "
                f"| {s.get('num_sliced', '-')} "
                f"| {counts.get('bf16', 0)}/{total} "
                f"| {fmt_s(s.get('modeled_epilogue_s'))} "
                f"| {s.get('hbm_bytes', 0):.2e} "
                f"| {fmt_s(s.get('wall_s'))} "
                f"| {'-' if xeb is None else f'{xeb:.4f}'} "
                f"| {rel_err} |"
            )
        print(
            f"| | | Δ | | | "
            f"{r.get('modeled_epilogue_speedup', 0):.2f}× faster | | | "
            f"xeb Δ {r.get('xeb_delta', 0):+.4f} | |"
        )


def print_serving_table(serving_dir="experiments/serving") -> None:
    """§Serving rows from ``benchmarks.bench_serving`` trajectory
    records: cold-vs-warm latency per circuit family, the coalesced
    batched-vs-serial throughput comparison, and the Poisson mixed-
    traffic steady state."""
    path = os.path.join(serving_dir, "trajectory.json")
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if isinstance(rec, dict):
            rows = rec.get("records", [])
    cw = [r for r in rows if r.get("kind") == "cold_warm"]
    bt = [r for r in rows if r.get("kind") == "batching"]
    po = [r for r in rows if r.get("kind") == "poisson"]
    if cw:
        print("\n### Serving: cold vs warm "
              "(plan cache across tenant bursts)\n")
        print("| family | tenants | cold p50 / p99 | warm p50 / p99 | "
              "warm p50 speedup | warm req/s |")
        print("|---|---|---|---|---|---|")
        for r in cw:
            print(
                f"| {r.get('family', '-')} | {r.get('tenants', '-')} "
                f"| {fmt_s(r.get('cold_p50_s'))} / "
                f"{fmt_s(r.get('cold_p99_s'))} "
                f"| {fmt_s(r.get('warm_p50_s'))} / "
                f"{fmt_s(r.get('warm_p99_s'))} "
                f"| {r.get('warm_p50_speedup', 0):.1f}× "
                f"| {r.get('warm_req_per_s', 0):.0f} |"
            )
    if bt:
        print("\n### Serving: coalesced batching vs serial "
              "(concurrent amplitude tenants, warm plans)\n")
        print("| family | tenants | batched req/s (p50) | "
              "serial req/s (p50) | gain |")
        print("|---|---|---|---|---|")
        for r in bt:
            print(
                f"| {r.get('family', '-')} | {r.get('tenants', '-')} "
                f"| {r.get('batched_req_per_s', 0):.0f} "
                f"({fmt_s(r.get('batched_p50_s'))}) "
                f"| {r.get('serial_req_per_s', 0):.0f} "
                f"({fmt_s(r.get('serial_p50_s'))}) "
                f"| {r.get('throughput_gain', 0):.2f}× |"
            )
    if po:
        print("\n### Serving: Poisson mixed traffic (steady state)\n")
        print("| families | requests | offered | served req/s | "
              "p50 | p99 | batched |")
        print("|---|---|---|---|---|---|---|")
        for r in po:
            print(
                f"| {r.get('families', '-')} | {r.get('requests', '-')} "
                f"| {r.get('offered_rate_hz', 0):.0f} Hz "
                f"| {r.get('req_per_s', 0):.0f} "
                f"| {fmt_s(r.get('p50_s'))} | {fmt_s(r.get('p99_s'))} "
                f"| {r.get('batched_fraction', 0)*100:.0f}% |"
            )


def main() -> None:
    recs = load()
    # ---------------- dry-run table (both meshes) ----------------
    print("### Dry-run matrix (lower + compile status, per-device memory)\n")
    print("| arch | shape | 16x16 | 2x16x16 | args/dev | temps/dev | "
          "collectives (single-pod) |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r1 = recs.get((arch, shape, False))
            r2 = recs.get((arch, shape, True))
            if r1 is None and r2 is None:
                continue
            def status(r):
                if r is None:
                    return "–"
                if "error" in r:
                    return "FAIL"
                if "skipped" in r:
                    return "skip"
                return "OK"
            mem = arg = coll = "-"
            if r1 and "roofline" in r1:
                m = r1["memory"]
                arg = fmt_bytes(m.get("argument_bytes"))
                mem = fmt_bytes(m.get("temp_bytes"))
                cb = r1["roofline"]["collective_bytes_per_device"]
                coll = ", ".join(
                    f"{k}:{fmt_bytes(v)}" for k, v in sorted(cb.items())
                ) or "none"
            print(f"| {arch} | {shape} | {status(r1)} | {status(r2)} "
                  f"| {arg} | {mem} | {coll} |")
    # ---------------- roofline table (single-pod) ----------------
    print("\n### Roofline (single-pod 16x16, per-device terms)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "bound | MODEL/HLO | frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, False))
            if r is None or "roofline" not in r:
                continue
            e = enrich(r)
            print(
                f"| {arch} | {shape} | {fmt_s(e['compute_s'])} "
                f"| {fmt_s(e['memory_s'])} | {fmt_s(e['collective_s'])} "
                f"| {e['dominant']} | {fmt_s(e['bound_s'])} "
                f"| {e['useful_ratio']:.2f} | {e['roofline_fraction']:.2f} |"
            )
    print_sampling_table()
    print_lowering_table()
    print_hoisting_table()
    print_memory_table()
    print_optimize_table()
    print_megakernel_table()
    print_obs_table()
    print_precision_table()
    print_distributed_table()
    print_serving_table()


if __name__ == "__main__":
    main()
