"""Deliverable (g) — roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json, adds the analytic FLOPs correction
(XLA:CPU undercounts scan bodies / overcounts cumsum — see
roofline/analytic.py) and prints one row per (arch × shape × mesh)."""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.parallel.sharding import count_params
from repro.roofline.analysis import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.roofline.analytic import cell_flops, cell_hbm_bytes


def enrich(rec: dict) -> dict | None:
    if "skipped" in rec or "error" in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    r = rec["roofline"]
    ana_flops = cell_flops(cfg, shape) / n_dev
    measured = r["flops_per_device"]
    # XLA:CPU scan-body undercount / cumsum overcount: trust the analytic
    # model when they disagree by >2x (methodology in EXPERIMENTS.md)
    corrected = ana_flops if not (0.5 <= measured / max(ana_flops, 1) <= 2.0) \
        else measured
    ana_bytes = cell_hbm_bytes(cfg, shape, rec["params"]) / n_dev
    mem_bytes = min(r["bytes_per_device"], max(ana_bytes, 1.0) * 4)
    compute_s = corrected / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    coll_s = r["collective_s"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    return {
        **rec,
        "flops_corrected_per_dev": corrected,
        "flops_measured_per_dev": measured,
        "analytic_bytes_per_dev": ana_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom,
        "bound_s": bound,
        "roofline_fraction": compute_s / bound if bound else 0.0,
        "useful_ratio": rec["model_flops_global"] / (corrected * n_dev),
    }


def run(dryrun_dir: str = "experiments/dryrun") -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        tag = os.path.basename(path)[:-5]
        if "error" in rec:
            rows.append(f"roofline_{tag},NaN,error")
            continue
        if "skipped" in rec:
            rows.append(f"roofline_{tag},NaN,skipped:{rec['skipped'][:40]}")
            continue
        e = enrich(rec)
        rows.append(
            f"roofline_{tag},{e['bound_s']*1e6:.1f},"
            f"dom={e['dominant']};compute_s={e['compute_s']:.3e};"
            f"memory_s={e['memory_s']:.3e};collective_s={e['collective_s']:.3e};"
            f"frac={e['roofline_fraction']:.3f};useful={e['useful_ratio']:.2f}"
        )
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
