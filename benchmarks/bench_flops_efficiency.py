"""Fig. 11 — FLOPS efficiency before/after branch merging.

Two views:
  1. *Modeled* efficiency on the F(M,N,K) surface — both the TPU surface
     (our target) and the Sunway surface (reproduces the paper's 4% → 20%
     single-precision story qualitatively).
  2. *Measured* CPU wall-time of the actual jitted contraction before and
     after merging + GEMM orientation on a mid-size network (the real
     executor, complex64).
"""

from __future__ import annotations

import numpy as np

from repro.core.executor import ContractionPlan
from repro.core.merging import (
    merge_branches,
    modeled_tree_time,
    orient_gemms,
)
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.merging import TPU_PEAK_FLOPS, SUNWAY_PEAK_FLOPS

from .common import network_for, timer


def modeled_efficiency(tree, S, surface: str, slice_fused: bool = False) -> float:
    """useful_flops / (peak × modeled_time), aggregated over the tree."""
    from repro.core.tensor_network import popcount

    t = modeled_tree_time(tree, S, surface, slice_fused=slice_fused)
    peak = TPU_PEAK_FLOPS if surface == "tpu" else SUNWAY_PEAK_FLOPS
    flops = 0.0
    for v in tree.children:
        nm = tree.node_mask(v)
        mult = 2.0 ** (popcount(S) - popcount(S & nm))
        flops += mult * 2.0 ** (popcount(nm & ~S) + 1)
    return flops / (t * peak)


def run(circuit: str = "syc-16") -> list[str]:
    tn, arrays = network_for(circuit)
    tree = random_greedy_tree(tn, repeats=8)
    target = max(tree.width() - 4, 8)
    S = find_slices(tree, target, method="lifetime")
    rows = []
    for surface in ("sunway", "tpu"):
        before = modeled_efficiency(tree, S, surface)
        res = merge_branches(tree, S, surface=surface)
        after = modeled_efficiency(res.tree, S, surface)
        rows.append(
            f"fig11_{surface}_efficiency,{after*100:.2f},"
            f"before={before*100:.2f}%;merges={res.merges}"
            + (";paper=4%->20%" if surface == "sunway" else "")
        )
        if surface == "tpu":
            fused = modeled_efficiency(res.tree, S, surface, slice_fused=True)
            rows.append(
                f"fig11_tpu_slice_fused,{fused*100:.2f},"
                f"beyond-paper K-concat of contracted slice groups"
            )
    # measured executor wall time (one slice, complex64, CPU)
    small_tn, small_arrays = network_for("syc-12")
    t0 = random_greedy_tree(small_tn, repeats=8)
    s0 = find_slices(t0, max(t0.width() - 2, 10), method="lifetime")
    plan_before = ContractionPlan(t0, s0)
    _, t_before = timer(
        lambda: np.asarray(plan_before.contract_all(small_arrays, slice_batch=1)),
        repeat=2,
    )
    merged = merge_branches(t0, s0).tree
    merged = orient_gemms(merged)
    plan_after = ContractionPlan(merged, s0)
    _, t_after = timer(
        lambda: np.asarray(plan_after.contract_all(small_arrays, slice_batch=1)),
        repeat=2,
    )
    rows.append(
        f"fig11_measured_contraction_ms,{t_after*1e3:.1f},"
        f"before={t_before*1e3:.1f}ms"
    )
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
