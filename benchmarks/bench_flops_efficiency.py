"""Fig. 11 — FLOPS efficiency before/after branch merging.

Three views:
  1. *Modeled* efficiency on the F(M,N,K) surface — both the TPU surface
     (our target) and the Sunway surface (reproduces the paper's 4% → 20%
     single-precision story qualitatively).
  2. *Measured* CPU wall-time of the actual jitted contraction before and
     after merging + GEMM orientation on a mid-size network (the real
     executor, complex64).
  3. *Backend comparison* on the stem workload: the einsum oracle path vs
     the lowered-GEMM kernel schedule (Sec. V lowering subsystem), with
     the schedule summary, appended as a trajectory record under
     ``experiments/lowering/``.
"""

from __future__ import annotations

import numpy as np

from repro.core.executor import ContractionPlan
from repro.core.merging import (
    merge_branches,
    modeled_tree_time,
    orient_gemms,
)
from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.merging import TPU_PEAK_FLOPS, SUNWAY_PEAK_FLOPS

from .common import append_trajectory, network_for, timer


def modeled_efficiency(tree, S, surface: str, slice_fused: bool = False) -> float:
    """useful_flops / (peak × modeled_time), aggregated over the tree."""
    from repro.core.tensor_network import popcount

    t = modeled_tree_time(tree, S, surface, slice_fused=slice_fused)
    peak = TPU_PEAK_FLOPS if surface == "tpu" else SUNWAY_PEAK_FLOPS
    flops = 0.0
    for v in tree.children:
        nm = tree.node_mask(v)
        mult = 2.0 ** (popcount(S) - popcount(S & nm))
        flops += mult * 2.0 ** (popcount(nm & ~S) + 1)
    return flops / (t * peak)


def run(circuit: str = "syc-16") -> list[str]:
    tn, arrays = network_for(circuit)
    tree = random_greedy_tree(tn, repeats=8)
    target = max(tree.width() - 4, 8)
    S = find_slices(tree, target, method="lifetime")
    rows = []
    for surface in ("sunway", "tpu"):
        before = modeled_efficiency(tree, S, surface)
        res = merge_branches(tree, S, surface=surface)
        after = modeled_efficiency(res.tree, S, surface)
        rows.append(
            f"fig11_{surface}_efficiency,{after*100:.2f},"
            f"before={before*100:.2f}%;merges={res.merges}"
            + (";paper=4%->20%" if surface == "sunway" else "")
        )
        if surface == "tpu":
            fused = modeled_efficiency(res.tree, S, surface, slice_fused=True)
            rows.append(
                f"fig11_tpu_slice_fused,{fused*100:.2f},"
                f"beyond-paper K-concat of contracted slice groups"
            )
    # measured executor wall time (one slice, complex64, CPU)
    small_tn, small_arrays = network_for("syc-12")
    t0 = random_greedy_tree(small_tn, repeats=8)
    s0 = find_slices(t0, max(t0.width() - 2, 10), method="lifetime")
    # pin the einsum oracle backend: these two rows quantify the merging
    # effect and must not silently follow REPRO_BACKEND
    plan_before = ContractionPlan(t0, s0, backend="einsum")
    _, t_before = timer(
        lambda: np.asarray(plan_before.contract_all(small_arrays, slice_batch=1)),
        repeat=2,
    )
    merged = merge_branches(t0, s0).tree
    merged = orient_gemms(merged)
    plan_after = ContractionPlan(merged, s0, backend="einsum")
    _, t_after = timer(
        lambda: np.asarray(plan_after.contract_all(small_arrays, slice_batch=1)),
        repeat=2,
    )
    rows.append(
        f"fig11_measured_contraction_ms,{t_after*1e3:.1f},"
        f"before={t_before*1e3:.1f}ms"
    )
    rows.extend(
        backend_comparison(merged, s0, small_arrays, einsum_wall=t_after)
    )
    return rows


def backend_comparison(
    tree, S, arrays,
    einsum_wall: float | None = None,
    trajectory_dir: str = "experiments/lowering",
) -> list[str]:
    """einsum vs lowered-GEMM executor wall time on the stem workload,
    plus a trajectory record appended to ``experiments/lowering/``.

    ``einsum_wall`` reuses an already-measured oracle-path timing of the
    same (tree, S, arrays) workload instead of re-running it.
    """
    walls: dict[str, float] = {}
    record: dict = {"workload": "syc-12 merged stem", "backends": {}}
    if einsum_wall is not None:
        walls["einsum"] = einsum_wall
        record["backends"]["einsum"] = {"wall_s": einsum_wall}
    for backend in ("einsum", "gemm"):
        if backend in walls:
            continue
        plan = ContractionPlan(tree, S, backend=backend)
        _, wall = timer(
            lambda: np.asarray(plan.contract_all(arrays, slice_batch=1)),
            repeat=2,
        )
        walls[backend] = wall
        rec = {"wall_s": wall}
        if plan.schedule is not None:
            rec["schedule"] = plan.schedule.summary()
        record["backends"][backend] = rec
    record["gemm_over_einsum"] = walls["gemm"] / walls["einsum"]
    append_trajectory([record], trajectory_dir)
    sched = record["backends"]["gemm"].get("schedule", {})
    counts = ";".join(
        f"{k}={v}" for k, v in sorted(sched.get("backends", {}).items())
    )
    return [
        f"fig11_backend_einsum_ms,{walls['einsum']*1e3:.1f},oracle path",
        f"fig11_backend_gemm_ms,{walls['gemm']*1e3:.1f},"
        f"lowered schedule {counts};"
        f"pad_waste={sched.get('pad_waste', 0.0)*100:.1f}%",
    ]


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
