"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (
        bench_distributed_scaling,
        bench_end_to_end,
        bench_flops_efficiency,
        bench_roofline,
        bench_sampling_throughput,
        bench_serving,
        bench_slice_count,
        bench_slicefinder_speed,
        bench_slicing_overhead,
    )

    import types

    precision = types.SimpleNamespace(run=bench_end_to_end.precision_rows)
    modules = [
        ("fig8", bench_slicefinder_speed),
        ("fig9", bench_slice_count),
        ("fig10", bench_slicing_overhead),
        ("fig11", bench_flops_efficiency),
        ("e2e", bench_end_to_end),
        ("precision", precision),
        ("sampling", bench_sampling_throughput),
        ("roofline", bench_roofline),
        ("distributed", bench_distributed_scaling),
        ("serving", bench_serving),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as e:  # keep the harness alive per-figure
            failures += 1
            print(f"{name}_FAILED,NaN,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(
            f"{name}_wall_s,{(time.perf_counter()-t0)*1e6:.0f},seconds="
            f"{time.perf_counter()-t0:.1f}",
            flush=True,
        )
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
