"""Fig. 9 — number of slicing indices found, per circuit family.

The paper's claim: the lifetime sliceFinder finds equal-or-smaller slicing
sets than greedy in most cases.  We also report the beyond-paper
interval-optimal sweep as the stem-relaxation lower bound.

``cooptimizer_rows`` adds the PR-5 comparison: the one-shot
pathfinder → slicer pipeline vs the anytime path–slice co-optimizer
(:func:`repro.optimize.plan_search`) at an equal evaluation budget —
per instance, |S| and hoist-aware executed FLOPs under the same
certified-peak byte budget (records appended to
``experiments/optimize/trajectory.json``)."""

from __future__ import annotations

import math

from repro.core.pathfinder import random_greedy_tree
from repro.core.slicing import find_slices
from repro.core.tensor_network import popcount
from repro.lowering.memory import certified_peak
from repro.lowering.partition import partition_tree
from repro.optimize import oneshot_plan, plan_search

from .common import append_trajectory, network_for, timer, trees_for


def run(circuits=("syc-8", "syc-12", "syc-16", "syc-20", "zn-12", "zn-16"),
        n_trees: int = 8) -> list[str]:
    rows = []
    wins = ties = losses = 0
    for name in circuits:
        tn, _ = network_for(name)
        trees = trees_for(tn, n_trees)
        nl = ng = ni = 0
        for i, tree in enumerate(trees):
            target = max(tree.width() - 4, 8)
            nl += popcount(find_slices(tree, target, method="lifetime"))
            ng += popcount(
                find_slices(tree, target, method="greedy", repeats=4, seed=i)
            )
            ni += popcount(find_slices(tree, target, method="interval"))
        rows.append(
            f"fig9_{name},{nl / n_trees:.2f},"
            f"greedy={ng / n_trees:.2f};interval={ni / n_trees:.2f}"
        )
        if nl < ng:
            wins += 1
        elif nl == ng:
            ties += 1
        else:
            losses += 1
    rows.append(f"fig9_summary,{wins},ties={ties};losses={losses}")
    rows.extend(cooptimizer_rows(circuits=circuits))
    return rows


def cooptimizer_rows(
    circuits=("syc-8", "syc-12", "syc-16", "syc-20", "zn-12", "zn-16"),
    max_evals: int = 32,
    num_workers: int = 4,
    seed: int = 0,
    json_dir: str | None = "experiments/optimize",
) -> list[str]:
    """One-shot pipeline vs anytime co-optimizer at an equal evaluation
    budget and the same certified-peak byte budget, per syc/zn instance."""
    rows: list[str] = []
    records: list[dict] = []
    wins = ties = losses = 0
    for name in circuits:
        tn, _ = network_for(name)
        w0 = random_greedy_tree(tn, repeats=8, seed=seed).width()
        target = max(w0 - 4, 8)
        shot, t_one = timer(oneshot_plan, tn, target, seed=seed)
        part = partition_tree(shot.tree, shot.smask) if shot.smask else None
        base_flops = (
            part.hoisted_cost() if part else shot.tree.total_cost()
        )
        base_peak = certified_peak(shot.tree, shot.smask, 8, part=part)
        res, t_search = timer(
            plan_search, tn, target, max_evals=max_evals,
            num_workers=num_workers, seed=seed,
        )
        if res.objective < base_flops:
            wins += 1
        elif res.objective == base_flops:
            ties += 1
        else:
            losses += 1
        # improvement vs the *external* staged pipeline (plan_search's
        # internal seed is already peak-refined, a better baseline)
        improve = base_flops / res.objective
        rows.append(
            f"coopt_{name},{res.num_sliced},"
            f"oneshot_S={popcount(shot.smask)};"
            f"log2flops={math.log2(base_flops):.2f}->"
            f"{math.log2(res.objective):.2f};"
            f"improve={improve:.2f}x;"
            f"budget_peak={res.budget_bytes}"
        )
        records.append(
            {
                "workload": name,
                "target_dim": target,
                "max_evals": max_evals,
                "num_workers": num_workers,
                "seed": seed,
                "num_sliced_oneshot": popcount(shot.smask),
                "num_sliced_coopt": res.num_sliced,
                "log2_flops_oneshot": math.log2(base_flops),
                "log2_flops_coopt": math.log2(res.objective),
                "improvement": improve,
                "peak_bytes_oneshot": base_peak,
                "peak_bytes_coopt": res.peak_bytes,
                "budget_bytes": res.budget_bytes,
                "feasible": res.feasible,
                "wall_oneshot_s": t_one,
                "wall_search_s": t_search,
                "trace": [
                    {
                        "evaluation": t.evaluation,
                        "log2_objective": t.log2_objective,
                        "num_sliced": t.num_sliced,
                        "move": t.move,
                    }
                    for t in res.trace
                ],
            }
        )
    rows.append(
        f"coopt_summary,{wins},ties={ties};losses={losses};"
        f"evals={max_evals}"
    )
    if json_dir is not None:
        append_trajectory(records, json_dir)
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
