"""Fig. 9 — number of slicing indices found, per circuit family.

The paper's claim: the lifetime sliceFinder finds equal-or-smaller slicing
sets than greedy in most cases.  We also report the beyond-paper
interval-optimal sweep as the stem-relaxation lower bound."""

from __future__ import annotations

from repro.core.slicing import find_slices
from repro.core.tensor_network import popcount

from .common import network_for, trees_for


def run(circuits=("syc-8", "syc-12", "syc-16", "syc-20", "zn-12", "zn-16"),
        n_trees: int = 8) -> list[str]:
    rows = []
    wins = ties = losses = 0
    for name in circuits:
        tn, _ = network_for(name)
        trees = trees_for(tn, n_trees)
        nl = ng = ni = 0
        for i, tree in enumerate(trees):
            target = max(tree.width() - 4, 8)
            nl += popcount(find_slices(tree, target, method="lifetime"))
            ng += popcount(
                find_slices(tree, target, method="greedy", repeats=4, seed=i)
            )
            ni += popcount(find_slices(tree, target, method="interval"))
        rows.append(
            f"fig9_{name},{nl / n_trees:.2f},"
            f"greedy={ng / n_trees:.2f};interval={ni / n_trees:.2f}"
        )
        if nl < ng:
            wins += 1
        elif nl == ng:
            ties += 1
        else:
            losses += 1
    rows.append(f"fig9_summary,{wins},ties={ties};losses={losses}")
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
