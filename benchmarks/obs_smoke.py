"""Observability smoke + tracer-overhead regression gate (CI artifact).

Runs the pinned syc-12 scan path twice on the *same* compiled artifact —
once with tracing off, once on — and asserts the traced/untraced wall
ratio stays under a bound (the off-path is free by construction; the
on-path must stay within budget).  Alongside the gate it exports the
run's telemetry as CI artifacts under ``experiments/obs/``:

    trace.jsonl        one Chrome complete-event per line (Perfetto-ready)
    metrics.json       counters/gauges/histograms + per-span aggregates
    overhead.json      the measured walls and their ratio
    calibration.md     model-vs-measured table per backend class

    PYTHONPATH=src python -m benchmarks.obs_smoke --assert-ratio 1.05
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

import repro.obs as obs
from repro.core import plan_contraction
from repro.core.executor import ContractionPlan
from repro.obs import trace

from .common import network_for, timer


def run(
    circuit: str = "syc-12",
    out_dir: str = "experiments/obs",
    repeat: int = 5,
    assert_ratio: float | None = None,
) -> dict:
    tn, arrays = network_for(circuit)
    from .bench_end_to_end import tree_width

    tree, smask, report = plan_contraction(
        tn, max(tree_width(tn) - 3, 10), seed=0,
        method="lifetime", tune=True, merge=True,
    )
    plan = ContractionPlan(tree, smask)

    # one untimed call first: jit compilation must not pollute either arm
    # (the artifact is shared — the toggle never joins the fingerprint)
    warm = np.asarray(plan.contract_all(arrays, slice_batch=4))

    prev = trace.enabled()
    try:
        trace.set_enabled(False)
        val_off, wall_off = timer(
            lambda: np.asarray(plan.contract_all(arrays, slice_batch=4)),
            repeat=repeat,
        )
        trace.set_enabled(True)
        obs.reset()
        val_on, wall_on = timer(
            lambda: np.asarray(plan.contract_all(arrays, slice_batch=4)),
            repeat=repeat,
        )
        assert val_off.tobytes() == val_on.tobytes() == warm.tobytes(), (
            "traced path changed the result!"
        )
        summary = obs.telemetry_summary()
        cal = obs.calibrate_plan(plan, arrays, repeat=1)
    finally:
        trace.set_enabled(prev)

    ratio = wall_on / wall_off if wall_off else float("inf")
    os.makedirs(out_dir, exist_ok=True)
    obs.dump_trace(os.path.join(out_dir, "trace.jsonl"))
    with open(os.path.join(out_dir, "metrics.json"), "w") as f:
        json.dump(summary, f, indent=2)
    overhead = {
        "workload": circuit,
        "repeat": repeat,
        "wall_untraced_s": wall_off,
        "wall_traced_s": wall_on,
        "ratio": ratio,
        "num_sliced": report.num_sliced,
    }
    with open(os.path.join(out_dir, "overhead.json"), "w") as f:
        json.dump(overhead, f, indent=2)
    with open(os.path.join(out_dir, "calibration.md"), "w") as f:
        f.write(cal.table() + "\n")

    print(f"untraced {wall_off*1e3:.1f}ms  traced {wall_on*1e3:.1f}ms  "
          f"ratio {ratio:.3f}")
    print(cal.table())
    if assert_ratio is not None and ratio > assert_ratio:
        raise SystemExit(
            f"tracer overhead regression: traced/untraced wall ratio "
            f"{ratio:.3f} > {assert_ratio} on {circuit}"
        )
    return overhead


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--circuit", default="syc-12")
    ap.add_argument("--out-dir", default="experiments/obs")
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument(
        "--assert-ratio", type=float, default=None,
        help="fail if traced/untraced wall exceeds this bound",
    )
    a = ap.parse_args()
    run(a.circuit, a.out_dir, repeat=a.repeat, assert_ratio=a.assert_ratio)


if __name__ == "__main__":
    main()
